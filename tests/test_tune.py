"""Schedule planner + autotuner subsystem (repro.tune).

Covers: cache round-trip + corruption tolerance, deterministic
candidate enumeration, the force-schedule escape hatch, and the
regression guarantee that tuned dispatch never selects an invalid
tiling (TilingError) — on any shape, including non-tileable ones.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tune
from repro.core.blockspec import derive_tiling
from repro.tune import planner
from repro.tune.cache import ScheduleCache
from repro.tune.schedule import Schedule, layout_signature, schedule_key


@pytest.fixture
def tmp_cache(tmp_path):
    """Pin the process-wide cache to a temp file for the test."""
    cache = tune.use_cache(tmp_path / "schedules.json")
    yield cache
    tune.use_cache(None)  # memory-only afterwards; never the user's file


# ---------------------------------------------------------------------------
# Schedule object + cache round-trip
# ---------------------------------------------------------------------------

def test_schedule_describe_parse_roundtrip():
    s = Schedule("matmul", "kernel", (("bm", 256), ("bn", 128), ("bk", 512)))
    assert Schedule.parse(s.describe(), op="matmul") == s
    assert Schedule.parse("xla", op="matmul") == Schedule("matmul", "xla")
    assert Schedule.from_dict(s.to_dict()) == s
    with pytest.raises(ValueError):
        Schedule("matmul", "nonsense")


def test_cache_roundtrip(tmp_path):
    path = tmp_path / "sub" / "schedules.json"
    c1 = ScheduleCache(path)
    key = schedule_key("matmul", ((256, 512), (512, 256)),
                       (jnp.float32, jnp.float32), "dense", "cpu")
    sched = Schedule("matmul", "kernel", (("bm", 128), ("bn", 128), ("bk", 256)))
    c1.put(key, sched, us=123.4, source="measured")
    assert path.exists()

    c2 = ScheduleCache(path)
    hit = c2.get(key)
    assert hit is not None
    assert hit.schedule == sched
    assert hit.us == 123.4
    assert hit.source == "measured"


def test_cache_tolerates_corruption(tmp_path):
    path = tmp_path / "schedules.json"
    path.write_text("{not json")
    c = ScheduleCache(path)
    assert len(c) == 0
    # planned entries stay in memory only
    c.put("k", Schedule("matmul", "xla"), source="planned", persist=False)
    assert json.loads(path.read_text()) if path.read_text().startswith("{\"") else True


def test_cache_versioning(tmp_path):
    path = tmp_path / "schedules.json"
    path.write_text(json.dumps({"version": 999, "entries": {"k": {}}}))
    assert len(ScheduleCache(path)) == 0


# ---------------------------------------------------------------------------
# planner: deterministic, Axe-validated enumeration
# ---------------------------------------------------------------------------

def test_enumeration_deterministic():
    kw = dict(shapes=((2048, 1024), (1024, 1536)),
              dtypes=(jnp.float32, jnp.float32), backend="tpu")
    a = planner.plan("matmul", **kw)
    b = planner.plan("matmul", **kw)
    assert [c.schedule for c in a] == [c.schedule for c in b]
    assert [c.cost_s for c in a] == [c.cost_s for c in b]
    assert len(a) > 1  # xla + at least one kernel tiling
    assert a == sorted(a, key=lambda c: (c.cost_s, c.schedule.describe()))


def test_kernel_candidates_are_axe_valid():
    m, k, n = 2048, 1280, 5440
    for c in planner.plan("matmul", shapes=((m, k), (k, n)),
                          dtypes=(jnp.bfloat16, jnp.bfloat16), backend="tpu",
                          impl="kernel"):
        bm, bn, bk = (c.schedule.block(x) for x in ("bm", "bn", "bk"))
        # must not raise: every candidate passed the direct-sum check
        derive_tiling((m, k), (bm, bk), jnp.bfloat16)
        derive_tiling((k, n), (bk, bn), jnp.bfloat16)
        derive_tiling((m, n), (bm, bn), jnp.bfloat16)


def test_untileable_shape_has_no_kernel_candidates():
    # 300 and 7 admit no MXU-aligned tiling -> only the XLA schedule
    cands = planner.plan("matmul", shapes=((300, 7), (7, 9)),
                         dtypes=(jnp.float32, jnp.float32), backend="tpu")
    assert cands
    assert all(c.schedule.impl == "xla" for c in cands)


def test_tpu_ranking_prefers_large_mxu_tiles():
    best = planner.plan("matmul", shapes=((2048, 1024), (1024, 1536)),
                        dtypes=(jnp.bfloat16, jnp.bfloat16), backend="tpu")[0]
    assert best.schedule.impl == "kernel"
    assert best.schedule.block("bm") == 512
    assert best.schedule.block("bn") == 512


def test_cpu_ranking_prefers_compiled_xla():
    best = planner.plan("matmul", shapes=((2048, 1024), (1024, 1536)),
                        dtypes=(jnp.float32, jnp.float32), backend="cpu")[0]
    assert best.schedule.impl == "xla"


def test_plan_all_ops():
    assert planner.plan("flash_attention", shapes=((1, 2, 256, 64), (1, 2, 256, 64)),
                        dtypes=(jnp.float32,))
    assert planner.plan("moe_gemm", shapes=((8, 256, 512), (8, 512, 256)),
                        dtypes=(jnp.float32,))
    assert planner.plan("mha_blocked", shapes=((1, 512, 8, 64), (1, 512, 8, 64)),
                        dtypes=(jnp.float32,))
    cm = planner.plan("collective_matmul", shapes=((256, 64), (64, 128), (8,)),
                      dtypes=(jnp.float32,))
    assert {c.schedule.impl for c in cm} == {"ring", "psum_scatter"}
    with pytest.raises(ValueError):
        planner.plan("unknown_op", shapes=((1,),), dtypes=(jnp.float32,))


# ---------------------------------------------------------------------------
# get_schedule resolution order + escape hatches
# ---------------------------------------------------------------------------

def test_force_schedule_context(tmp_cache):
    kw = dict(shapes=((256, 512), (512, 256)), dtypes=(jnp.float32, jnp.float32))
    with tune.force_schedule("kernel:bm=128,bn=128,bk=256"):
        s = tune.get_schedule("matmul", **kw)
    assert s == Schedule("matmul", "kernel", (("bm", 128), ("bn", 128), ("bk", 256)))
    # nested None re-enables planning
    with tune.force_schedule("xla"):
        with tune.force_schedule(None):
            s2 = tune.get_schedule("matmul", **kw)
    assert s2 == tune.get_schedule("matmul", **kw)


def test_force_schedule_env(tmp_cache, monkeypatch):
    monkeypatch.setenv(tune.FORCE_ENV, "xla")
    s = tune.get_schedule("matmul", shapes=((2048, 1024), (1024, 1536)),
                          dtypes=(jnp.float32, jnp.float32))
    assert s == Schedule("matmul", "xla")


def test_disable_env_returns_legacy_defaults(tmp_cache, monkeypatch):
    monkeypatch.setenv(tune.DISABLE_ENV, "1")
    s = tune.get_schedule("matmul", shapes=((2048, 1024), (1024, 1536)),
                          dtypes=(jnp.float32, jnp.float32))
    assert s == tune.DEFAULT_SCHEDULES["matmul"]


def test_cached_measurement_wins_over_plan(tmp_cache):
    kw = dict(shapes=((2048, 1024), (1024, 1536)),
              dtypes=(jnp.float32, jnp.float32), backend="cpu")
    pinned = Schedule("matmul", "kernel", (("bm", 256), ("bn", 256), ("bk", 512)))
    key = schedule_key("matmul", kw["shapes"], kw["dtypes"], "dense", "cpu")
    tmp_cache.put(key, pinned, us=1.0, source="measured")
    assert tune.get_schedule("matmul", **kw) == pinned


def test_forced_spec_falls_through_for_inapplicable_op(tmp_cache):
    # "xla" is valid for matmul but not flash_attention: the force must
    # apply to the former and quietly not apply to the latter
    with tune.force_schedule("xla"):
        m = tune.get_schedule("matmul", shapes=((256, 512), (512, 256)),
                              dtypes=(jnp.float32, jnp.float32))
        fa = tune.get_schedule("flash_attention",
                               shapes=((1, 2, 256, 64), (1, 2, 256, 64)),
                               dtypes=(jnp.float32, jnp.float32))
    assert m.impl == "xla"
    assert fa.impl == "kernel"
    with pytest.raises(ValueError):  # malformed specs still raise
        with tune.force_schedule("kernel:bm=abc"):
            tune.get_schedule("matmul", shapes=((256, 512), (512, 256)),
                              dtypes=(jnp.float32, jnp.float32))


def test_measured_entry_reaches_kernel_restricted_query(tmp_cache):
    # the autotuner persists under the unrestricted key; a kernel-only
    # dispatch query must still see it when the impls agree
    shapes = ((256, 512), (512, 256))
    dtypes = (jnp.float32, jnp.float32)
    measured = Schedule("matmul", "kernel", (("bm", 128), ("bn", 128), ("bk", 256)))
    key = schedule_key("matmul", shapes, dtypes, "dense", "cpu")
    tmp_cache.put(key, measured, us=42.0, source="measured")
    s = tune.get_schedule("matmul", shapes=shapes, dtypes=dtypes,
                          backend="cpu", impl="kernel")
    assert s == measured


def test_save_persists_only_measurements(tmp_path):
    c = ScheduleCache(tmp_path / "schedules.json")
    c.put("planned-key", Schedule("matmul", "xla"), source="planned", persist=False)
    c.put("measured-key", Schedule("matmul", "xla"), us=1.0, source="measured")
    raw = json.loads((tmp_path / "schedules.json").read_text())
    assert set(raw["entries"]) == {"measured-key"}
    # but the planned entry is still live in memory
    assert c.get("planned-key") is not None


def test_no_duplicate_candidates_after_clamping():
    fa = planner.plan("flash_attention", shapes=((1, 2, 256, 64), (1, 2, 256, 64)),
                      dtypes=(jnp.float32,))
    descs = [c.schedule.describe() for c in fa]
    assert len(descs) == len(set(descs))
    mb = planner.plan("mha_blocked", shapes=((1, 128, 8, 64), (1, 128, 8, 64)),
                      dtypes=(jnp.float32,))
    descs = [c.schedule.describe() for c in mb]
    assert len(descs) == len(set(descs))


def test_mha_blocked_has_default_and_total_plan(tmp_cache, monkeypatch):
    # disabled-planner path must have a default for every planned op
    monkeypatch.setenv(tune.DISABLE_ENV, "1")
    s = tune.get_schedule("mha_blocked", shapes=((1, 512, 8, 64), (1, 512, 8, 64)),
                          dtypes=(jnp.float32,))
    assert s == tune.DEFAULT_SCHEDULES["mha_blocked"]
    monkeypatch.delenv(tune.DISABLE_ENV)
    # awkward lengths still plan (single-chunk fallback), never KeyError
    cands = planner.plan("mha_blocked", shapes=((1, 1000, 8, 64), (1, 1000, 8, 64)),
                         dtypes=(jnp.float32,))
    assert cands and cands[0].schedule.block("chunk") == 1000
    s2 = tune.get_schedule("mha_blocked", shapes=((1, 1000, 8, 64), (1, 1000, 8, 64)),
                           dtypes=(jnp.float32,))
    assert s2.block("chunk") == 1000


def test_candidate_blocks_largest_aligned_divisor():
    from repro.core.blockspec import candidate_blocks

    assert candidate_blocks(24, minimum=8) == (24,)     # not the fragmented (8,)
    assert candidate_blocks(4, minimum=8) == (4,)       # sub-atom dim: whole dim
    assert candidate_blocks(1024, minimum=128) == (512, 256, 128)
    assert candidate_blocks(13, minimum=8) == ()        # truly untileable


def test_program_resolves_schedule_per_call(tmp_cache):
    # stage schedules resolve outside the cached jit launcher, so a
    # measurement recorded after the first call takes effect on the next
    from repro.kernels import programs

    a = jax.random.normal(jax.random.PRNGKey(0), (256, 512), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (512, 256), jnp.float32)
    first = programs.matmul(a, b, stage="tile", impl="kernel")
    measured = Schedule("matmul/tile", "kernel",
                        (("bm", 128), ("bn", 128), ("bk", 128)))
    key = schedule_key("matmul/tile", (a.shape, b.shape), (a.dtype, b.dtype),
                       "dense", jax.default_backend())
    tmp_cache.put(key, measured, us=1.0, source="measured")
    second = programs.matmul(a, b, stage="tile", impl="kernel")
    np.testing.assert_allclose(first, a @ b, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(second, a @ b, rtol=2e-4, atol=2e-4)
    assert tune.get_schedule("matmul/tile", shapes=(a.shape, b.shape),
                             dtypes=(a.dtype, b.dtype), impl="kernel") == measured


def test_autotune_flash_unmeasurable_returns_planner_pick(tmp_cache):
    # off-TPU, a large flash shape has no measurable candidates: the
    # autotuner returns the planner's pick unmeasured instead of raising
    q = jnp.zeros((1, 8, 1024, 64), jnp.float32)
    rep = tune.autotune_flash_attention(q, q, q)
    assert rep.schedule.impl == "kernel"
    assert rep.us != rep.us  # NaN: not measured
    assert not rep.measurements
    assert not tmp_cache.path.exists()  # nothing persisted


# ---------------------------------------------------------------------------
# regression: tuned dispatch never selects an invalid tiling
# ---------------------------------------------------------------------------

def test_tuned_dispatch_never_raises_tiling_error(tmp_cache):
    from repro.core.scopes import Scope, scope
    from repro.kernels import programs

    key = jax.random.PRNGKey(0)
    # aligned, odd, sub-atom, and prime shapes
    for (m, k, n) in [(256, 512, 256), (300, 70, 9), (128, 384, 640), (17, 13, 29)]:
        a = jax.random.normal(jax.random.fold_in(key, m), (m, k), jnp.float32)
        b = jax.random.normal(jax.random.fold_in(key, n), (k, n), jnp.float32)
        with scope(Scope.DEVICE):
            got = programs.matmul(a, b)  # must not raise TilingError
        np.testing.assert_allclose(
            got, a @ b, rtol=2e-4, atol=2e-4,
        )


def test_autotune_matmul_populates_and_hits_cache(tmp_cache):
    a = jax.random.normal(jax.random.PRNGKey(0), (256, 512), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (512, 256), jnp.float32)
    rep = tune.autotune_matmul(a, b, top_k=2, iters=1)
    assert not rep.cached and rep.measurements
    assert tmp_cache.path.exists()
    rep2 = tune.autotune_matmul(a, b)
    assert rep2.cached
    assert rep2.schedule == rep.schedule
    # dispatch now resolves to the measured winner under the stage key
    s = tune.get_schedule("matmul/tile", shapes=(a.shape, b.shape),
                          dtypes=(a.dtype, b.dtype))
    assert s == rep.schedule


# ---------------------------------------------------------------------------
# cost-model plumbing
# ---------------------------------------------------------------------------

def test_schedule_time_terms():
    from repro.launch.roofline import schedule_time

    t, terms = schedule_time(flops=1e12, mem_bytes=1e9, backend="tpu")
    assert t == max(terms.values())
    assert set(terms) == {"compute", "memory", "collective"}
    t_cpu, _ = schedule_time(flops=1e12, mem_bytes=1e9, backend="cpu")
    assert t_cpu > t  # cpu peaks are far lower


def test_hlo_refined_xla_candidate():
    from repro.launch import hlo_cost

    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = hlo_cost.analyze_jit(lambda a, b: a @ b, a, a)
    assert c.flops == 2 * 64**3
    cands = planner.plan("matmul", shapes=((64, 64), (64, 64)),
                         dtypes=(jnp.float32, jnp.float32), use_hlo=True)
    assert cands[0].schedule.impl in ("xla", "kernel")


def test_layout_signature():
    from repro.core.layout import It, Layout

    assert layout_signature(None, None) == "dense"
    L1 = Layout((It(2, 8, "m"), It(8, 1, "m")))
    L2 = Layout((It(16, 1, "m"),))  # canonically equal
    assert layout_signature(L1) == layout_signature(L2)
    assert layout_signature(L1) != "dense"

"""Paper Fig. 12 — GEMM + Reduce-Scatter: fused/overlapped vs unfused.

Runs in a subprocess with 8 host-platform devices (so the main process
and other benches keep seeing 1 device). Compares:
  * unfused — the collective_matmul program's psum_scatter variant
    (full local GEMM then reduce-scatter, the cuBLAS+NCCL analogue)
  * fused   — the ring variant of the same program stage
    (collective_matmul/kshard — one tune key, two schedules)
and reports wall-time plus the layout-inferred collective plan bytes.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import row

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import collective as coll
from repro.core.dtensor import DTensorSpec
from repro.kernels import programs

mesh = compat.make_mesh((8,), ("model",))
M, K, N = 1024, 2048, 1024
a = jax.random.normal(jax.random.PRNGKey(0), (M, K), jnp.float32)
b = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)

def run(mode):
    def body(a, b):
        return programs.collective_matmul(
            a, b, axis_name="model",
            impl="ring" if mode == "fused" else "psum_scatter")
    f = jax.jit(compat.shard_map(body, mesh=mesh,
                in_specs=(P(None, "model"), P("model", None)),
                out_specs=P("model", None), check_vma=False))
    out = f(a, b); jax.block_until_ready(out)
    ts = []
    for _ in range(5):
        t0 = time.perf_counter(); jax.block_until_ready(f(a, b))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts)//2] * 1e6, out

us_u, out_u = run("unfused")
us_f, out_f = run("fused")
err = float(jnp.max(jnp.abs(out_u - out_f)))

# layout-pair collective inference (Fig. 8): partial sums over model ->
# dst shards dim0 on model => ReduceScatter
ms = {"model": 8}
src = DTensorSpec.from_pspec((M, N), (None, None), ms)
dst = DTensorSpec.from_pspec((M, N), ("model", None), ms)
plan = coll.infer_redistribution(src, dst, ms, partial_axes=("model",))
pbytes = coll.plan_comm_bytes(plan, src, ms, 4)
print(json.dumps({"us_unfused": us_u, "us_fused": us_f, "err": err,
                  "plan": [type(s).__name__ for s in plan], "plan_bytes": pbytes}))
"""


def run() -> list:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         os.path.dirname(os.path.dirname(__file__))] + sys.path
    )
    out = subprocess.run(
        [sys.executable, "-c", _CHILD], capture_output=True, text=True, env=env
    )
    if out.returncode != 0:
        return [row("gemm_rs.error", 0.0, out.stderr.strip()[-120:].replace(",", ";"))]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    return [
        row("gemm_rs.unfused", data["us_unfused"], "full GEMM + psum_scatter"),
        row("gemm_rs.fused", data["us_fused"],
            f"ring overlap; err={data['err']:.1e}; plan={'+'.join(data['plan'])}"
            f"; plan_bytes={data['plan_bytes']}"),
    ]

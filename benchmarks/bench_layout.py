"""Paper §3.3 — layout-operator throughput: the compiler-side cost of
canonicalize / group / tile / tile_of / slice, which run at trace time
for every operator dispatch."""
from __future__ import annotations

import time

from benchmarks.common import row
from repro.core import (
    canonicalize, direct_sum, group, slice_layout, strided, tile, tile_of,
)


def _timeit(fn, iters=2000) -> float:
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> list:
    L = strided((4, 8, 4, 8), (2048, 64, 16, 1))
    A = strided((8, 8), (8, 1))
    B = strided((128, 128), (128, 1))
    T, _ = tile(A, (8, 8), B, (128, 128))
    rows = [
        row("layout.canonicalize", _timeit(lambda: canonicalize(L)), "4-iter layout"),
        row("layout.group", _timeit(lambda: group(L, (32, 32))), "to rank-2"),
        row("layout.tile", _timeit(lambda: tile(A, (8, 8), B, (128, 128))), "8x8 ⊗ 128x128"),
        row("layout.tile_of", _timeit(lambda: tile_of(T, (1024, 1024), B, (128, 128)), iters=500), "recover C"),
        row("layout.slice", _timeit(lambda: slice_layout(L, (8, 8), (16, 16), (32, 32))), "16x16 region"),
        row("layout.direct_sum", _timeit(lambda: direct_sum(A, (8, 8), B, (128, 128))), "strided atom"),
    ]
    return rows

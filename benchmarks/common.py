"""Benchmark timing helpers."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Sequence

import jax

#: merged perf-baseline file the --program benchmark modes write
#: (override the directory with $REPRO_BENCH_DIR)
BENCH_KERNELS_JSON = "BENCH_kernels.json"


def time_jitted(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time (µs) of a jitted callable on this host."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"


def bench_json_path(filename: str = BENCH_KERNELS_JSON) -> str:
    return os.path.join(os.environ.get("REPRO_BENCH_DIR", "."), filename)


def write_bench_json(
    section: str, rows: Sequence[str], *, backend: str = "",
    filename: str = BENCH_KERNELS_JSON,
) -> str:
    """Merge one benchmark's rows into a ``BENCH_*.json`` baseline file
    (``BENCH_kernels.json`` by default; ``bench_graph`` writes
    ``BENCH_graph.json``), keyed by section so benchmarks share one
    baseline file later PRs diff against. Rows are the ``row()``
    strings; parsed here so the JSON carries structured
    ``us``/``derived`` fields."""
    path = bench_json_path(filename)
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError):
        data = {"version": 1, "sections": {}}
    parsed = {}
    for r in rows:
        name, us, derived = r.split(",", 2)
        parsed[name] = {"us": float(us), "derived": derived}
    data["sections"][section] = {
        "backend": backend or jax.default_backend(),
        "rows": parsed,
    }
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
        f.write("\n")
    return path

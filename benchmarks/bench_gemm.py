"""Paper Fig. 10 — GEMM throughput across real-model weight shapes.

On this CPU container we time the XLA schedule (the MESH-scope dispatch
of the ``matmul`` program) at reduced batch and validate the Pallas
kernel (the DEVICE-scope ``matmul/tile`` stage) in interpret mode; the
derived column reports achieved GFLOP/s and the Axe-verified MXU tiling
the kernel would use on TPU. Weight shapes follow the paper's eval set
(Qwen3 / LLaMA-3.1 / Gemma-2), scaled 1/4 in each dim to keep CPU
wall-time sane.

Modes (``python benchmarks/bench_gemm.py [--default | --tuned | --program]``):

  --default  time the fixed default dispatch only
  --tuned    additionally run the autotuner per shape (populating the
             on-disk schedule cache at ``repro.tune.default_cache_path()``
             or ``$REPRO_TUNE_CACHE``) and report tuned vs default µs
  --program  benchmark the axe.program DSL path against the legacy
             deprecated-shim path (same schedules) and write the
             ``BENCH_kernels.json`` perf baseline
"""
from __future__ import annotations

import pathlib
import sys

if __package__ in (None, ""):  # script mode: make `benchmarks.*` importable
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_jitted, write_bench_json
from repro.core.blockspec import derive_tiling, pick_tile
from repro.kernels import programs, ref as kref

# (name, M(batch), K, N) — paper weight shapes / 4
SHAPES = [
    ("qwen3-8b.qkv", 2048, 1024, 1536),
    ("qwen3-32b.mlp", 2048, 1280, 5440),
    ("llama3-8b.mlp", 2048, 1024, 3584),
    ("gemma2-9b.mlp", 2048, 896, 3584),
    ("gpt3-175b.attn", 2048, 3072, 3072),
]

#: interpret-mode kernel comparison shape for --program (small enough
#: that the Python-interpreted Pallas body is not the whole budget)
PROGRAM_SHAPE = (256, 512, 256)
PROGRAM_BLOCKS = dict(bm=128, bn=128, bk=256)


def run(mode: str = "default") -> list:
    from repro import tune

    tuned = mode == "tuned"
    rows = []
    key = jax.random.PRNGKey(0)
    for name, m, k, n in SHAPES:
        k1, k2 = jax.random.split(jax.random.fold_in(key, hash(name) % 2**31))
        a = jax.random.normal(k1, (m, k), jnp.float32)
        b = jax.random.normal(k2, (k, n), jnp.float32)
        fn = jax.jit(lambda a, b: programs.matmul(a, b))
        us = time_jitted(fn, a, b)
        gflops = 2 * m * k * n / (us * 1e-6) / 1e9
        tile = pick_tile((m, n), jnp.bfloat16)
        d = derive_tiling((m, n), tile, jnp.bfloat16)
        rows.append(row(f"gemm.{name}", us,
                        f"{gflops:.1f}GFLOP/s xla; tpu_tile={tile} mxu={d.mxu_aligned}"))
        if tuned:
            rep = tune.autotune_matmul(a, b)
            # delta against the default (XLA) candidate measured in the
            # same autotune loop — back-to-back, so not timing noise
            meas = dict(rep.measurements)
            base = meas.get("xla")
            if rep.cached or base is None:
                derived = f"sched={rep.schedule.describe()} cached={rep.cached}"
            else:
                delta = (base - rep.us) / base * 100.0
                derived = (f"sched={rep.schedule.describe()} "
                           f"default={base:.1f}us delta={delta:+.1f}%")
            rows.append(row(f"gemm.{name}.tuned", rep.us, derived))
    # kernel-vs-oracle validation at one shape (interpret mode)
    a = jax.random.normal(key, (256, 512), jnp.float32)
    b = jax.random.normal(key, (512, 256), jnp.float32)
    got = programs.matmul(a, b, stage="tile", impl="kernel",
                          blocks=PROGRAM_BLOCKS)
    err = float(jnp.max(jnp.abs(got - kref.matmul_ref(a, b))))
    rows.append(row("gemm.pallas_check", 0.0, f"max_err={err:.2e}"))
    if tuned:
        from repro.tune import cache as tcache

        c = tune.default_cache()
        path = c.path if c.path is not None else tcache.default_cache_path()
        rows.append(row("gemm.schedule_cache", 0.0, f"entries={len(c)} path={path}"))
    return rows


def run_program_mode() -> list:
    """DSL path vs the raw pinned launcher, identical schedules, plus
    the MESH-scope dispatch at the paper shapes — the perf baseline
    later PRs diff against (BENCH_kernels.json). (The legacy
    ``kernels.ops`` shim this used to compare against was removed after
    its deprecation window.)"""
    from repro.kernels.matmul import matmul_pallas

    rows = []
    m, k, n = PROGRAM_SHAPE
    a = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)

    us_prog = time_jitted(
        lambda a, b: programs.matmul(a, b, stage="tile", impl="kernel",
                                     blocks=PROGRAM_BLOCKS), a, b)
    us_launch = time_jitted(
        lambda a, b: matmul_pallas(a, b,
                                   block_m=PROGRAM_BLOCKS["bm"],
                                   block_n=PROGRAM_BLOCKS["bn"],
                                   block_k=PROGRAM_BLOCKS["bk"],
                                   interpret=jax.default_backend() != "tpu"),
        a, b)
    delta = (us_launch - us_prog) / us_launch * 100.0
    rows.append(row("gemm.program.kernel", us_prog,
                    f"matmul/tile kernel:{PROGRAM_BLOCKS}"))
    rows.append(row("gemm.launcher.kernel", us_launch,
                    f"matmul_pallas pinned blocks; program delta={delta:+.1f}%"))

    for name, m, k, n in SHAPES[:2]:
        k1, k2 = jax.random.split(jax.random.fold_in(jax.random.PRNGKey(0),
                                                     hash(name) % 2**31))
        a = jax.random.normal(k1, (m, k), jnp.float32)
        b = jax.random.normal(k2, (k, n), jnp.float32)
        us_p = time_jitted(jax.jit(lambda a, b: programs.matmul(a, b)), a, b)
        rows.append(row(f"gemm.program.{name}", us_p, "mesh dispatch (dot stage)"))
    path = write_bench_json("gemm", rows)
    rows.append(row("gemm.bench_json", 0.0, f"path={path}"))
    return rows


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    g = ap.add_mutually_exclusive_group()
    g.add_argument("--tuned", action="store_true",
                   help="autotune each shape and report tuned vs default")
    g.add_argument("--default", dest="default_", action="store_true",
                   help="fixed default schedules only (the default)")
    g.add_argument("--program", dest="program_", action="store_true",
                   help="DSL-vs-legacy-shim comparison; writes BENCH_kernels.json")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    rows = run_program_mode() if args.program_ else \
        run("tuned" if args.tuned else "default")
    for line in rows:
        print(line)
        sys.stdout.flush()


if __name__ == "__main__":
    main()

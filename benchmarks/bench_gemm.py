"""Paper Fig. 10 — GEMM throughput across real-model weight shapes.

On this CPU container we time the XLA schedule (the MESH-scope dispatch)
at reduced batch and validate the Pallas kernel (the DEVICE-scope
schedule) in interpret mode; the derived column reports achieved
GFLOP/s and the Axe-verified MXU tiling the kernel would use on TPU.
Weight shapes follow the paper's eval set (Qwen3 / LLaMA-3.1 / Gemma-2),
scaled 1/4 in each dim to keep CPU wall-time sane.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_jitted
from repro.core import ops as cops
from repro.core.blockspec import derive_tiling, pick_tile
from repro.kernels import ops as kops, ref as kref

# (name, M(batch), K, N) — paper weight shapes / 4
SHAPES = [
    ("qwen3-8b.qkv", 2048, 1024, 1536),
    ("qwen3-32b.mlp", 2048, 1280, 5440),
    ("llama3-8b.mlp", 2048, 1024, 3584),
    ("gemma2-9b.mlp", 2048, 896, 3584),
    ("gpt3-175b.attn", 2048, 3072, 3072),
]


def run() -> list:
    rows = []
    key = jax.random.PRNGKey(0)
    for name, m, k, n in SHAPES:
        k1, k2 = jax.random.split(jax.random.fold_in(key, hash(name) % 2**31))
        a = jax.random.normal(k1, (m, k), jnp.float32)
        b = jax.random.normal(k2, (k, n), jnp.float32)
        fn = jax.jit(lambda a, b: cops.matmul(a, b))
        us = time_jitted(fn, a, b)
        gflops = 2 * m * k * n / (us * 1e-6) / 1e9
        tile = pick_tile((m, n), jnp.bfloat16)
        d = derive_tiling((m, n), tile, jnp.bfloat16)
        rows.append(row(f"gemm.{name}", us,
                        f"{gflops:.1f}GFLOP/s xla; tpu_tile={tile} mxu={d.mxu_aligned}"))
    # kernel-vs-oracle validation at one shape (interpret mode)
    a = jax.random.normal(key, (256, 512), jnp.float32)
    b = jax.random.normal(key, (512, 256), jnp.float32)
    got = kops.matmul(a, b, block_m=128, block_n=128, block_k=256)
    err = float(jnp.max(jnp.abs(got - kref.matmul_ref(a, b))))
    rows.append(row("gemm.pallas_check", 0.0, f"max_err={err:.2e}"))
    return rows

"""End-to-end compiled-forward benchmark: ``axe.compile`` executables
over the model-zoo graphs (dense / MoE / SSM smoke configs), reporting
wall time and tokens/s per config, merged into ``BENCH_graph.json`` for
the nightly regression gate (``benchmarks/check_regression.py``).

The default run measures each config twice — through the fusion passes
(``repro.axe.passes``, the gated ``graph.forward.*`` rows) and unfused
(``graph.forward.*.unfused``) — so the baseline carries the fused vs
unfused tokens/s side by side. ``--no-fuse`` is the A/B switch: it
measures only the unfused executables and overwrites the section with
them (a debugging mode — don't commit its output as the baseline).

Usage:
    python benchmarks/bench_graph.py [--batch 4] [--seq 64] [--no-fuse]
"""
from __future__ import annotations

import argparse
import dataclasses
import pathlib
import sys

if __package__ in (None, ""):  # script mode: make `benchmarks.*` importable
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_jitted, write_bench_json

BENCH_GRAPH_JSON = "BENCH_graph.json"

ARCHS = ("qwen3-4b", "qwen3-moe-235b-a22b", "mamba2-2.7b")


def _build(axe, cfg, mesh, params, batch, seq, *, fuse):
    exe = axe.model_executable(cfg, mesh, batch, seq, dtype=cfg.dtype,
                               fuse=fuse)
    return exe, axe.model_inputs(exe.graph, cfg, params)


def _interleaved(execs, tokens, *, warmup: int = 3, rounds: int = 25):
    """Best wall-time (µs) per executable, sampled in drift-symmetric
    rounds: each round runs the legs forward then reversed (A,B,B,A), so
    a linear host-load drift across the round hits every leg equally —
    the fused and unfused legs run identical layouts, and a sequential
    A-then-B sweep would let a few ms of machine noise decide the
    comparison. Min over rounds because the host is shared: the fastest
    observation is the least-contended one."""
    import time

    for exe, inputs in execs:
        for _ in range(warmup):
            jax.block_until_ready(exe(inputs, tokens))
    samples = [[] for _ in execs]
    order = list(range(len(execs)))
    for _ in range(rounds):
        for i in order + order[::-1]:
            exe, inputs = execs[i]
            t0 = time.perf_counter()
            jax.block_until_ready(exe(inputs, tokens))
            samples[i].append(time.perf_counter() - t0)
    return [min(ts) * 1e6 for ts in samples]


def run_offload(batch: int, seq: int) -> list:
    """One host-offload row: the dense config compiled with its
    embedding table parked on a carved host-class mesh axis
    (``model_executable(classes=..., offload=...)``), interleaved
    against the same graph all-accelerator on the same 3-axis mesh.
    Checks bit-level parity before timing — the Transfer collective
    lowers to the same SPMD primitives, only the cost model differs."""
    import numpy as np

    from repro import axe, compat
    from repro.configs import get_config, smoke_variant
    from repro.models.model_zoo import build_model

    n_dev = len(jax.devices())
    host_deg = 2 if n_dev % 2 == 0 else 1
    rest = n_dev // host_deg
    model_deg = 2 if rest % 2 == 0 else rest
    mesh = compat.make_mesh(
        (rest // model_deg, model_deg, host_deg), ("data", "model", "host")
    )

    arch = "qwen3-4b"
    cfg = smoke_variant(get_config(arch))
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(
        jax.random.PRNGKey(1), (batch * seq,), 0, cfg.vocab_size, jnp.int32
    )
    exe_a = axe.model_executable(cfg, mesh, batch, seq, dtype=cfg.dtype,
                                 classes={"host": "host"})
    exe_h = axe.model_executable(cfg, mesh, batch, seq, dtype=cfg.dtype,
                                 classes={"host": "host"}, offload=("embed",))
    ins_a = axe.model_inputs(exe_a.graph, cfg, params)
    ins_h = axe.model_inputs(exe_h.graph, cfg, params)
    out_a = np.asarray(jax.block_until_ready(exe_a(ins_a, tokens)))
    out_h = np.asarray(jax.block_until_ready(exe_h(ins_h, tokens)))
    err = float(np.max(np.abs(out_a - out_h)))
    if err > 1e-5:
        raise RuntimeError(f"host-parked forward deviates by {err:.2e}")
    transfers = sum(
        1 for (_op, _operand, steps) in exe_h.collective_sequence()
        if "Transfer" in steps
    )
    us_a, us_h = _interleaved([(exe_a, ins_a), (exe_h, ins_h)], tokens)
    tok_h = batch * seq / (us_h / 1e6)
    tok_a = batch * seq / (us_a / 1e6)
    return [row(
        f"graph.forward.{arch}.offload", us_h,
        f"compiled forward {batch}x{seq} embed host-parked "
        f"tokens/s={tok_h:.0f} (all-accel {tok_a:.0f}) "
        f"transfers={transfers} xfer={exe_h.plan.total_transfer_bytes}B/dev "
        f"max|d|={err:.1e}",
    )]


def run_overlap(batch: int, seq: int) -> list:
    """One compute/communication-overlap row: the MoE config (comm is a
    meaningful fraction of its step) compiled with the overlap schedule
    (``model_executable(..., overlap=True)``, docs/overlap.md) against
    the synchronous executable on the *same solved plan*, so the A/B
    isolates the schedule. Bit-comparability is asserted before timing
    (the schedule reorders collective issue only), and the legs share
    the drift-symmetric interleaved rounds (:func:`_interleaved`) so the
    tokens/s delta is not measurement drift."""
    import numpy as np

    from repro import axe, compat
    from repro.configs import get_config, smoke_variant
    from repro.models.model_zoo import build_model

    n_dev = len(jax.devices())
    model_deg = 4 if n_dev % 4 == 0 else n_dev
    mesh = compat.make_mesh((n_dev // model_deg, model_deg), ("data", "model"))

    arch = "qwen3-moe-235b-a22b"
    cfg = smoke_variant(get_config(arch))
    cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(
        jax.random.PRNGKey(1), (batch * seq,), 0, cfg.vocab_size, jnp.int32
    )
    exe_s = axe.model_executable(cfg, mesh, batch, seq, dtype=cfg.dtype)
    exe_o = axe.model_executable(cfg, mesh, batch, seq, dtype=cfg.dtype,
                                 plan=exe_s.solve_result, overlap=True)
    ins = axe.model_inputs(exe_s.graph, cfg, params)
    out_s = np.asarray(jax.block_until_ready(exe_s(ins, tokens)))
    out_o = np.asarray(jax.block_until_ready(exe_o(ins, tokens)))
    if not np.array_equal(out_s, out_o):
        err = float(np.max(np.abs(out_s - out_o)))
        raise RuntimeError(f"overlap forward is not bit-equal (max|d|={err:.2e})")
    prefetched = sum(len(r.prefetched) for r in exe_o.lowering_trace)
    if prefetched == 0:
        raise RuntimeError("overlap schedule hoisted no collectives")
    # the solver's view of the same plan under the overlap objective:
    # how many ops get their comm charged at max(comm, compute)
    res = axe.solve(exe_s.graph, overlap=True)
    hidden_ops = sum(1 for d in res.trace if d.hidden_comm_s > 0)
    us_s, us_o = _interleaved([(exe_s, ins), (exe_o, ins)], tokens)
    tok_s = batch * seq / (us_s / 1e6)
    tok_o = batch * seq / (us_o / 1e6)
    return [row(
        f"graph.forward.{arch}.overlap", us_o,
        f"compiled forward {batch}x{seq} overlap tokens/s={tok_o:.0f} "
        f"(sync {tok_s:.0f}) prefetched={prefetched} "
        f"hidden_ops={hidden_ops} "
        f"hidden={res.hidden_comm_s * 1e6:.1f}us/dev bit-equal",
    )]


def run_cotune(batch: int, seq: int) -> list:
    """One cotune row: the dense config compiled through the
    solve<->tune fixed-point loop (``model_executable(cotune=True,
    cotune_measure=True)``, docs/cotune.md) against the one-shot-solved
    executable. The cotune leg autotunes the solver's matmul locals and
    re-solves under the measured-corrected cost model, so its plan may
    legitimately differ from the one-shot plan; numerics are checked to
    tolerance (layout changes reassociate float reductions) and the two
    legs share the drift-symmetric interleaved rounds
    (:func:`_interleaved`) so the tokens/s delta is not measurement
    drift."""
    import numpy as np

    from repro import axe, compat, tune
    from repro.configs import get_config, smoke_variant
    from repro.models.model_zoo import build_model

    n_dev = len(jax.devices())
    model_deg = 4 if n_dev % 4 == 0 else n_dev
    mesh = compat.make_mesh((n_dev // model_deg, model_deg), ("data", "model"))

    arch = "qwen3-4b"
    cfg = smoke_variant(get_config(arch))
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(
        jax.random.PRNGKey(1), (batch * seq,), 0, cfg.vocab_size, jnp.int32
    )
    exe_1 = axe.model_executable(cfg, mesh, batch, seq, dtype=cfg.dtype)
    exe_c = axe.model_executable(cfg, mesh, batch, seq, dtype=cfg.dtype,
                                 cotune=True, cotune_measure=True)
    ct = exe_c.cotune_report
    if ct is None:
        raise RuntimeError("cotune executable carries no cotune_report")
    if ct.objective_s > ct.iter0_objective_s * (1 + 1e-9):
        raise RuntimeError(
            f"cotune regressed the modeled objective: "
            f"{ct.iter0_objective_s:.6e} -> {ct.objective_s:.6e}"
        )
    ins_1 = axe.model_inputs(exe_1.graph, cfg, params)
    ins_c = axe.model_inputs(exe_c.graph, cfg, params)
    out_1 = np.asarray(jax.block_until_ready(exe_1(ins_1, tokens)))
    out_c = np.asarray(jax.block_until_ready(exe_c(ins_c, tokens)))
    err = float(np.max(np.abs(out_1 - out_c)))
    if err > 1e-5:
        raise RuntimeError(f"cotuned forward deviates by {err:.2e}")
    us_1, us_c = _interleaved([(exe_1, ins_1), (exe_c, ins_c)], tokens)
    tok_1 = batch * seq / (us_1 / 1e6)
    tok_c = batch * seq / (us_c / 1e6)
    cm = ct.cost_model
    table = len(cm) if cm is not None else 0
    # the schedule cache now holds this run's measured entries; the
    # nightly workflow merges it into the persistent service artifact
    tune.ServiceArtifact.from_cache(tune.default_cache()).save(
        "bench_out/schedule_service.json"
    )
    return [row(
        f"graph.forward.{arch}.cotune", us_c,
        f"compiled forward {batch}x{seq} cotuned tokens/s={tok_c:.0f} "
        f"(one-shot {tok_1:.0f}) iters={len(ct.iterations)} "
        f"converged={ct.converged} flipped={ct.flipped} "
        f"J={ct.iter0_objective_s * 1e3:.2f}->"
        f"{ct.objective_s * 1e3:.2f}ms table={table} "
        f"max|d|={err:.1e}",
    )]


def run(batch: int, seq: int, *, fuse: bool = True) -> list:
    from repro import axe, compat
    from repro.configs import get_config, smoke_variant
    from repro.models.model_zoo import build_model

    n_dev = len(jax.devices())
    model_deg = 4 if n_dev % 4 == 0 else n_dev
    mesh = compat.make_mesh((n_dev // model_deg, model_deg), ("data", "model"))

    rows = []
    for arch in ARCHS:
        cfg = smoke_variant(get_config(arch))
        if cfg.is_moe:
            cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        tokens = jax.random.randint(
            jax.random.PRNGKey(1), (batch * seq,), 0, cfg.vocab_size, jnp.int32
        )
        exe_u, ins_u = _build(axe, cfg, mesh, params, batch, seq, fuse=False)
        base = (
            f"compiled forward {batch}x{seq} "
            f"collectives={len(exe_u.collective_sequence())} "
            f"comm={exe_u.plan.total_comm_bytes}B/dev"
        )
        if not fuse:
            us_u = time_jitted(exe_u, ins_u, tokens)
            tok_u = batch * seq / (us_u / 1e6)
            rows.append(row(
                f"graph.forward.{arch}", us_u,
                f"{base} tokens/s={tok_u:.0f} (no-fuse mode)",
            ))
            continue
        exe_f, ins_f = _build(axe, cfg, mesh, params, batch, seq, fuse=True)
        us_u, us_f = _interleaved([(exe_u, ins_u), (exe_f, ins_f)], tokens)
        tok_u = batch * seq / (us_u / 1e6)
        tok_f = batch * seq / (us_f / 1e6)
        rep = exe_f.fusion_report
        rows.append(row(
            f"graph.forward.{arch}", us_f,
            f"compiled forward {batch}x{seq} fused tokens/s={tok_f:.0f} "
            f"(unfused {tok_u:.0f}) patterns={len(rep.patterns_fired)} "
            f"collectives={len(exe_f.collective_sequence())} "
            f"comm={exe_f.plan.total_comm_bytes}B/dev",
        ))
        rows.append(row(
            f"graph.forward.{arch}.unfused", us_u,
            f"{base} tokens/s={tok_u:.0f}",
        ))
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--no-fuse", action="store_true",
                    help="measure only the unfused executables (A/B "
                         "debugging; overwrites the section — don't "
                         "commit as the baseline)")
    ap.add_argument("--offload", action="store_true",
                    help="also measure the dense config with its "
                         "embedding table host-parked (repro.axe.hetero) "
                         "against the all-accelerator twin")
    ap.add_argument("--overlap", action="store_true",
                    help="also measure the MoE config under the "
                         "compute/communication-overlap schedule "
                         "(docs/overlap.md) against its synchronous twin "
                         "on the same solved plan")
    ap.add_argument("--cotune", action="store_true",
                    help="also measure the dense config through the "
                         "solve<->tune fixed-point loop (repro.axe.cotune, "
                         "docs/cotune.md) against its one-shot-solved twin; "
                         "exports the run's measured schedules to "
                         "bench_out/schedule_service.json")
    args = ap.parse_args()
    rows = run(args.batch, args.seq, fuse=not args.no_fuse)
    if args.offload:
        rows += run_offload(args.batch, args.seq)
    if args.overlap:
        rows += run_overlap(args.batch, args.seq)
    if args.cotune:
        rows += run_cotune(args.batch, args.seq)
    path = write_bench_json(
        "graph", rows, filename=BENCH_GRAPH_JSON,
    )
    for r in rows:
        print(r)
    print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

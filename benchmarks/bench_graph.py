"""End-to-end compiled-forward benchmark: ``axe.compile`` executables
over the model-zoo graphs (dense / MoE / SSM smoke configs), reporting
wall time and tokens/s per config, merged into ``BENCH_graph.json`` for
the nightly regression gate (``benchmarks/check_regression.py``).

Usage:
    python benchmarks/bench_graph.py [--batch 4] [--seq 64]
"""
from __future__ import annotations

import argparse
import dataclasses
import pathlib
import sys

if __package__ in (None, ""):  # script mode: make `benchmarks.*` importable
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_jitted, write_bench_json

BENCH_GRAPH_JSON = "BENCH_graph.json"

ARCHS = ("qwen3-4b", "qwen3-moe-235b-a22b", "mamba2-2.7b")


def run(batch: int, seq: int) -> list:
    from repro import axe, compat
    from repro.configs import get_config, smoke_variant
    from repro.models.model_zoo import build_model

    n_dev = len(jax.devices())
    model_deg = 4 if n_dev % 4 == 0 else n_dev
    mesh = compat.make_mesh((n_dev // model_deg, model_deg), ("data", "model"))

    rows = []
    for arch in ARCHS:
        cfg = smoke_variant(get_config(arch))
        if cfg.is_moe:
            cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        tokens = jax.random.randint(
            jax.random.PRNGKey(1), (batch * seq,), 0, cfg.vocab_size, jnp.int32
        )
        exe = axe.model_executable(cfg, mesh, batch, seq, dtype=cfg.dtype)
        inputs = axe.model_inputs(exe.graph, cfg, params)
        us = time_jitted(exe, inputs, tokens)
        tok_s = batch * seq / (us / 1e6)
        rows.append(row(
            f"graph.forward.{arch}", us,
            f"compiled forward {batch}x{seq} tokens/s={tok_s:.0f} "
            f"collectives={len(exe.collective_sequence())} "
            f"comm={exe.plan.total_comm_bytes}B/dev",
        ))
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()
    rows = run(args.batch, args.seq)
    path = write_bench_json(
        "graph", rows, filename=BENCH_GRAPH_JSON,
    )
    for r in rows:
        print(r)
    print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Kernel benchmark regression gate for the scheduled CI bench job.

Compares a freshly measured ``BENCH_kernels.json`` (written by the
``--program`` modes of bench_gemm / bench_mha via
``benchmarks.common.write_bench_json``) against the committed baseline
and fails when any row regresses more than ``--threshold`` (default
20%). Rows present in only one file are reported — a measured row with
no baseline is called out as **new, ungated** (it has no regression
budget at all), and ``--strict-new`` turns those into a nonzero exit so
the nightly gate forces every new benchmark row to land with a
committed baseline instead of silently riding ungated. Without
``--strict-new`` they never fail the gate — a renamed row should fail
loudly in review, not here.

Usage:
    python benchmarks/check_regression.py \
        --baseline BENCH_kernels.json --current bench_out/BENCH_kernels.json \
        [--strict-new]
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple


def find_regressions(
    baseline: Dict, current: Dict, threshold: float = 0.20
) -> Tuple[List[str], List[str], List[str]]:
    """(regressions, notes, new_rows) comparing two BENCH_*.json payloads.

    A row regresses when ``current_us > baseline_us * (1 + threshold)``.
    ``new_rows`` lists measured rows with no baseline — they carry no
    regression budget (ungated) until a baseline is committed; the CLI's
    ``--strict-new`` turns a nonempty list into a failure. Notes cover
    everything else informational: missing rows/sections, improvements.
    """
    regressions: List[str] = []
    notes: List[str] = []
    new_rows: List[str] = []
    base_sections = baseline.get("sections", {})
    cur_sections = current.get("sections", {})
    for section in sorted(set(base_sections) | set(cur_sections)):
        b_rows = base_sections.get(section, {}).get("rows", {})
        c_rows = cur_sections.get(section, {}).get("rows", {})
        if not b_rows:
            notes.append(f"{section}: new section (no baseline)")
        if not c_rows:
            notes.append(f"{section}: missing from current run")
        for name in sorted(set(b_rows) | set(c_rows)):
            if name not in b_rows:
                new_rows.append(f"{section}/{name}: new row, ungated "
                                f"(no baseline to regress against)")
                continue
            if name not in c_rows:
                notes.append(f"{section}/{name}: missing from current run")
                continue
            b_us = float(b_rows[name]["us"])
            c_us = float(c_rows[name]["us"])
            if b_us <= 0:
                continue
            ratio = c_us / b_us
            if ratio > 1.0 + threshold:
                regressions.append(
                    f"{section}/{name}: {b_us:.1f} -> {c_us:.1f} us "
                    f"(+{100 * (ratio - 1):.1f}% > +{100 * threshold:.0f}% budget)"
                )
            elif ratio < 1.0 - threshold:
                notes.append(
                    f"{section}/{name}: improved {b_us:.1f} -> {c_us:.1f} us "
                    f"({100 * (1 - ratio):.1f}% faster)"
                )
    return regressions, notes, new_rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_kernels.json",
                    help="committed baseline JSON")
    ap.add_argument("--current", required=True,
                    help="freshly measured JSON to gate")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="allowed slowdown fraction (0.20 = +20%%)")
    ap.add_argument("--strict-new", action="store_true",
                    help="fail (exit 1) when the current run measures "
                         "rows absent from the baseline — the nightly "
                         "gate's mode, so new benchmark rows must land "
                         "with a committed baseline instead of riding "
                         "ungated")
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)

    regressions, notes, new_rows = find_regressions(
        baseline, current, args.threshold
    )
    for n in notes:
        print(f"note: {n}")
    for n in new_rows:
        print(f"{'STRICT-NEW' if args.strict_new else 'note'}: {n}")
    failed = False
    if regressions:
        print(f"\n{len(regressions)} kernel regression(s) past "
              f"+{100 * args.threshold:.0f}%:")
        for r in regressions:
            print(f"  REGRESSION {r}")
        failed = True
    if args.strict_new and new_rows:
        print(f"\n{len(new_rows)} new, ungated row(s) (--strict-new): "
              f"commit a baseline for them")
        failed = True
    if failed:
        return 1
    print(f"\nno regressions past +{100 * args.threshold:.0f}% "
          f"(baseline {args.baseline}, current {args.current})")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Serving benchmark: a synthetic many-user request stream through the
continuous batcher (``repro.serve.ContinuousBatcher``) over the
compiled decode executable, reporting decode tokens/s and per-request
p50/p99 completion latency (in scheduler steps) per model family,
merged into ``BENCH_serve.json`` for the nightly regression gate
(``benchmarks/check_regression.py``).

The stream is deterministic (seeded prompt lengths / arrival gaps), so
runs are comparable across commits; latency is measured in decode
steps, not wall-clock, keeping the gate host-independent — the wall
metric is the ``us`` column (median decode-step time), from which
tokens/s derives.

Usage:
    python benchmarks/bench_serve.py [--slots 4] [--max-seq 64]
        [--requests 12] [--new-tokens 8] [--archs qwen3-4b,...]
"""
from __future__ import annotations

import argparse
import dataclasses
import pathlib
import sys
import time

if __package__ in (None, ""):  # script mode: make `benchmarks.*` importable
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

import numpy as np

from benchmarks.common import row, write_bench_json

BENCH_SERVE_JSON = "BENCH_serve.json"

ARCHS = ("qwen3-4b", "mamba2-2.7b")


def synth_requests(n: int, max_seq: int, new_tokens: int, vocab: int,
                   seed: int = 0) -> list:
    """A deterministic arrival trace: prompt lengths 3..max_prompt,
    arrivals in bursts (0-2 step gaps) — enough churn that slots join
    and leave mid-stream."""
    from repro.serve import Request

    rng = np.random.RandomState(seed)
    max_prompt = max(4, min(max_seq - new_tokens - 1, 12))
    reqs, arrival = [], 0
    for uid in range(1, n + 1):
        s = int(rng.randint(3, max_prompt + 1))
        reqs.append(Request(
            uid=uid,
            prompt=rng.randint(0, vocab, size=s).astype(np.int32),
            max_new_tokens=new_tokens,
            arrival=arrival,
        ))
        arrival += int(rng.randint(0, 3))
    return reqs


def run(slots: int, max_seq: int, n_requests: int, new_tokens: int,
        archs) -> list:
    import jax

    from repro.configs import get_config, smoke_variant
    from repro.models.model_zoo import build_model
    from repro.serve import ContinuousBatcher, ServeEngine

    rows = []
    for arch in archs:
        cfg = smoke_variant(get_config(arch))
        if cfg.is_moe:
            cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        engine = ServeEngine(api=api, batch_size=slots, max_seq=max_seq)
        engine.load(params)
        reqs = synth_requests(n_requests, max_seq, new_tokens, cfg.vocab_size)

        # warmup: compile prefill + the decode executable
        warm = ContinuousBatcher(engine)
        warm.run([dataclasses.replace(reqs[0], uid=10_000)])

        bat = ContinuousBatcher(engine)
        t0 = time.perf_counter()
        results = bat.run(reqs)
        wall_s = time.perf_counter() - t0

        assert len(results) == n_requests
        total_tokens = sum(len(r.tokens) for r in results.values())
        steps = bat.step_count
        us_per_step = wall_s / max(steps, 1) * 1e6
        tok_s = total_tokens / wall_s
        lat = np.sort(np.asarray(
            [r.finished - r.submitted for r in results.values()], np.float64
        ))
        p50 = float(np.percentile(lat, 50))
        p99 = float(np.percentile(lat, 99))
        rows.append(row(
            f"serve.stream.{arch}", us_per_step,
            f"tokens/s={tok_s:.0f} total_tokens={total_tokens} "
            f"steps={steps} p50_steps={p50:.1f} p99_steps={p99:.1f} "
            f"slots={slots} requests={n_requests}",
        ))
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--archs", type=str, default=",".join(ARCHS))
    args = ap.parse_args()
    rows = run(args.slots, args.max_seq, args.requests, args.new_tokens,
               tuple(a for a in args.archs.split(",") if a))
    path = write_bench_json("serve", rows, filename=BENCH_SERVE_JSON)
    for r in rows:
        print(r)
    print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Paper Fig. 11 — MoE layer latency vs input tokens (Qwen3-30B-A3B
configuration family, width-reduced for CPU).

Measures the full fused path (route → sort dispatch → grouped SwiGLU →
combine) and the naive all-experts baseline (what the fused pipeline
beats in the paper), across token counts.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_jitted
from repro.configs import get_config, smoke_variant
from repro.models import moe as moe_mod

TOKENS = [32, 128, 512, 2048]


def _dense_baseline(p, x, cfg):
    """Every expert on every token (no dispatch) — the unfused reference."""
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, cfg.experts_per_tok)
    gate = gate / gate.sum(-1, keepdims=True)
    hg = jnp.einsum("td,edf->tef", xf, p["wg"])
    hu = jnp.einsum("td,edf->tef", xf, p["wu"])
    out = jnp.einsum("tef,efd->ted", jax.nn.silu(hg) * hu, p["wo"])
    sel = jnp.take_along_axis(out, idx[:, :, None], axis=1)
    return (sel * gate[:, :, None]).sum(1).reshape(b, s, d)


def run() -> list:
    # Qwen3-MoE family, reduced: keep 128 experts' structure at 1/4 width
    cfg = dataclasses.replace(
        smoke_variant(get_config("qwen3-moe-235b-a22b")),
        d_model=256, num_experts=32, experts_per_tok=8, expert_d_ff=192,
    )
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    rows = []
    for t in TOKENS:
        x = jax.random.normal(jax.random.PRNGKey(t), (1, t, cfg.d_model), jnp.float32)
        fused = jax.jit(lambda p, x: moe_mod.moe_apply(p, x, cfg))
        base = jax.jit(lambda p, x: _dense_baseline(p, x, cfg))
        us_f = time_jitted(fused, p, x)
        us_b = time_jitted(base, p, x)
        rows.append(row(f"moe.fused.t{t}", us_f, f"speedup_vs_dense={us_b/us_f:.2f}x"))
        rows.append(row(f"moe.dense.t{t}", us_b, f"experts={cfg.num_experts} top{cfg.experts_per_tok}"))
    return rows

"""Paper Fig. 13 — multi-head attention across input lengths (the
Trainium workload, adapted): full-materialization attention vs the
blocked online-softmax schedule (identical math to the Pallas kernel),
plus a kernel-vs-oracle check in interpret mode.

Modes (``python benchmarks/bench_mha.py [--default | --tuned | --program]``):

  --default  fixed chunk=256 blocked schedule
  --tuned    autotune the blocked schedule's chunk size per length
             (persisted in the schedule cache) and report the delta
  --program  benchmark the axe.program flash-attention path against the
             legacy deprecated-shim path (same blocks) and append to the
             ``BENCH_kernels.json`` perf baseline
"""
from __future__ import annotations

import functools
import pathlib
import sys

if __package__ in (None, ""):  # script mode: make `benchmarks.*` importable
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_jitted, write_bench_json
from repro.kernels import programs, ref as kref
from repro.models import attention as attn_mod

LENS = [512, 1024, 2048]


def run(mode: str = "default") -> list:
    from repro import tune

    tuned = mode == "tuned"
    rows = []
    cfgish = type("C", (), {"num_heads": 8, "num_kv_heads": 8, "head_dim": 64})()
    b, h, hd = 1, 8, 64
    for s in LENS:
        ks = jax.random.split(jax.random.PRNGKey(s), 3)
        q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, h, hd), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, h, hd), jnp.float32)
        full = jax.jit(functools.partial(attn_mod._gqa_full, cfg=None, causal=False, window=None))
        blocked = jax.jit(functools.partial(
            attn_mod._gqa_blocked, cfg=None, causal=False, window=None, chunk=256))
        us_full = time_jitted(full, q, k, v)
        us_blk = time_jitted(blocked, q, k, v)
        flops = 4 * b * h * s * s * hd
        rows.append(row(f"mha.full.s{s}", us_full, f"{flops/(us_full*1e-6)/1e9:.1f}GFLOP/s"))
        rows.append(row(f"mha.blocked.s{s}", us_blk, f"{flops/(us_blk*1e-6)/1e9:.1f}GFLOP/s"))
        if tuned:
            rep = tune.autotune_mha_blocked(q, k, v)
            meas = dict(rep.measurements)
            base = meas.get("xla:chunk=256")  # the --default chunk
            if rep.cached or base is None:
                derived = f"sched={rep.schedule.describe()} cached={rep.cached}"
            else:
                delta = (base - rep.us) / base * 100.0
                derived = (f"sched={rep.schedule.describe()} "
                           f"default={base:.1f}us delta={delta:+.1f}%")
            rows.append(row(f"mha.blocked.s{s}.tuned", rep.us, derived))
    # Pallas kernel check (interpret) on one shape
    q = jax.random.normal(jax.random.PRNGKey(7), (1, 2, 256, 64), jnp.float32)
    kk = jax.random.normal(jax.random.PRNGKey(8), (1, 2, 256, 64), jnp.float32)
    vv = jax.random.normal(jax.random.PRNGKey(9), (1, 2, 256, 64), jnp.float32)
    got = programs.flash_attention(q, kk, vv, causal=True)
    err = float(jnp.max(jnp.abs(got - kref.attention_ref(q, kk, vv, causal=True))))
    rows.append(row("mha.pallas_check", 0.0, f"max_err={err:.2e}"))
    return rows


def run_program_mode() -> list:
    """DSL path vs the raw pinned launcher for the flash-attention
    kernel (interpret mode, identical blocks), appended to
    BENCH_kernels.json. (The legacy ``kernels.ops`` shim this used to
    compare against was removed after its deprecation window.)"""
    from repro.kernels.flash_attention import flash_attention_pallas

    rows = []
    q = jax.random.normal(jax.random.PRNGKey(7), (1, 2, 256, 64), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(8), (1, 2, 256, 64), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(9), (1, 2, 256, 64), jnp.float32)
    blocks = {"bq": 128, "bkv": 128}
    us_prog = time_jitted(
        lambda q, k, v: programs.flash_attention(q, k, v, causal=True,
                                                 blocks=blocks), q, k, v)
    us_launch = time_jitted(
        lambda q, k, v: flash_attention_pallas(
            q, k, v, causal=True, block_q=128, block_kv=128,
            interpret=jax.default_backend() != "tpu"), q, k, v)
    delta = (us_launch - us_prog) / us_launch * 100.0
    rows.append(row("mha.program.kernel", us_prog,
                    "flash_attention/attend kernel:bq=128,bkv=128"))
    rows.append(row("mha.launcher.kernel", us_launch,
                    f"flash_attention_pallas pinned blocks; program delta={delta:+.1f}%"))
    # the MESH-scope blocked-softmax schedule at one paper length
    s = 1024
    ks = jax.random.split(jax.random.PRNGKey(s), 3)
    qb = jax.random.normal(ks[0], (1, s, 8, 64), jnp.float32)
    kb = jax.random.normal(ks[1], (1, s, 8, 64), jnp.float32)
    vb = jax.random.normal(ks[2], (1, s, 8, 64), jnp.float32)
    blocked = jax.jit(functools.partial(
        attn_mod._gqa_blocked, cfg=None, causal=False, window=None, chunk=256))
    rows.append(row(f"mha.program.blocked.s{s}", time_jitted(blocked, qb, kb, vb),
                    "mha_blocked xla:chunk=256"))
    path = write_bench_json("mha", rows)
    rows.append(row("mha.bench_json", 0.0, f"path={path}"))
    return rows


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    g = ap.add_mutually_exclusive_group()
    g.add_argument("--tuned", action="store_true",
                   help="autotune the blocked chunk size per length")
    g.add_argument("--default", dest="default_", action="store_true",
                   help="fixed default schedules only (the default)")
    g.add_argument("--program", dest="program_", action="store_true",
                   help="DSL-vs-legacy-shim comparison; appends to BENCH_kernels.json")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    rows = run_program_mode() if args.program_ else \
        run("tuned" if args.tuned else "default")
    for line in rows:
        print(line)
        sys.stdout.flush()


if __name__ == "__main__":
    main()

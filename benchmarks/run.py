"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.

  bench_gemm    — Fig. 10 (GEMM throughput, real weight shapes)
  bench_moe     — Fig. 11 (MoE layer latency vs tokens)
  bench_gemm_rs — Fig. 12 (GEMM+ReduceScatter fused vs unfused, 8-dev)
  bench_mha     — Fig. 13 (MHA across lengths; kernel check)
  bench_layout  — §3.3  (layout-operator trace-time cost)
"""
import sys


def main() -> None:
    from benchmarks import bench_gemm, bench_gemm_rs, bench_layout, bench_mha, bench_moe

    print("name,us_per_call,derived")
    for mod in (bench_layout, bench_gemm, bench_mha, bench_moe, bench_gemm_rs):
        for line in mod.run():
            print(line)
            sys.stdout.flush()


if __name__ == "__main__":
    main()

"""Quickstart: the Axe layout algebra and how the framework uses it.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (
    DTensorSpec, It, Layout, canonicalize,
    slice_layout, strided, tile, tile_of, za,
)
from repro.core.blockspec import derive_tiling


def main():
    # --- 1. An Axe layout: the paper's tensor-core example (§2.2) -----
    L = Layout(
        D=(It(8, 4, "lane"), It(2, 1, "warp"), It(4, 1, "lane"), It(2, 1, "reg")),
        R=(It(2, 4, "warp"),),
        O=za(warp=5),
    )
    print("tensor-core tile layout:", L)
    print("  f(0,0) ->", sorted(map(str, L.call_shaped((0, 0), (8, 16)))))
    print("  span per axis:", L.span())

    # --- 2. Tiling (Kronecker) and recovery ---------------------------
    A = strided((2, 3), (3, 1))
    B = strided((8, 8), (8, 1))
    T, S_T = tile(A, (2, 3), B, (8, 8))
    print("\n(2x3 of 8x8 tiles) =", T)
    C, S_C = tile_of(T, (16, 24), B, (8, 8))
    print("recovered outer layout:", C, "shape", S_C)

    # --- 3. Slicing ----------------------------------------------------
    Ld = strided((2, 8, 3, 8), (192, 8, 64, 1))
    sl = slice_layout(Ld, (0, 8), (8, 16), (16, 24))
    print("\nslice [0:8, 8:24]:", canonicalize(sl))

    # --- 4. Distributed tensors: Axe <-> PartitionSpec ----------------
    mesh_shape = {"data": 16, "model": 16}
    spec = DTensorSpec.from_pspec((8192, 4096), ("data", "model"), mesh_shape)
    print("\nDTensor layout for S0S1 sharding:", spec.layout)
    print("round-trips to pspec:", spec.pspec(mesh_shape))

    # --- 5. Kernel tiling derivation (BlockSpec from Axe) -------------
    d = derive_tiling((4096, 8192), (256, 512), jnp.bfloat16)
    print("\nPallas grid for 4096x8192 bf16 tiled 256x512:", d.grid,
          "| vreg aligned:", d.vreg_aligned, "| mxu aligned:", d.mxu_aligned)

    # --- 5b. The kernel DSL: programs of scope-tagged stages ----------
    # (docs/kernel-dsl.md) — one definition, dispatched by execution
    # scope; schedules resolve under program/stage tune keys
    from repro.core.scopes import Scope, scope as exec_scope
    from repro.kernels import programs

    print("\n" + programs.matmul.describe())
    a = jax.random.normal(jax.random.PRNGKey(3), (256, 512), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(4), (512, 256), jnp.float32)
    y_mesh = programs.matmul(a, b)            # MESH scope -> XLA dot
    with exec_scope(Scope.DEVICE):            # DEVICE scope -> Pallas tile stage
        y_dev = programs.matmul(a, b, blocks={"bm": 128, "bn": 128, "bk": 256})
    print("matmul program: mesh-vs-device max err:",
          float(jnp.max(jnp.abs(y_mesh - y_dev))))

    # --- 6. A tiny model forward --------------------------------------
    from repro.configs import get_config, smoke_variant
    from repro.models.model_zoo import ShapeSpec, build_model

    cfg = smoke_variant(get_config("qwen3-4b"))
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = api.make_train_batch(jax.random.PRNGKey(1), ShapeSpec("s", "train", 64, 2))
    loss = api.loss_fn(params, batch)
    print("\nsmoke qwen3-4b loss:", float(loss))


if __name__ == "__main__":
    main()

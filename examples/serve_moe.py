"""Serve a small MoE model with batched requests (prefill + decode).

Run:  PYTHONPATH=src python examples/serve_moe.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.models.model_zoo import build_model
from repro.serve.engine import ServeEngine


def main():
    cfg = dataclasses.replace(
        smoke_variant(get_config("dbrx-132b")), num_layers=4, d_model=256
    )
    print(f"serving {cfg.name}: {cfg.num_experts}e top-{cfg.experts_per_tok}, "
          f"{cfg.param_count()/1e6:.1f}M params")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))

    batch, max_seq, new_tokens = 4, 128, 16
    engine = ServeEngine(api, batch_size=batch, max_seq=max_seq, temperature=0.0)
    engine.load(params)

    prompts = jax.random.randint(jax.random.PRNGKey(1), (batch, 32), 0, cfg.vocab_size, jnp.int32)
    t0 = time.perf_counter()
    out = engine.generate(prompts, max_new_tokens=new_tokens)
    dt = time.perf_counter() - t0
    print(f"generated {batch}x{new_tokens} tokens in {dt:.2f}s "
          f"({batch * new_tokens / dt:.1f} tok/s)")
    for i in range(batch):
        print(f"  request {i}: {out[i].tolist()}")

    # temperature sampling
    engine2 = ServeEngine(api, batch_size=batch, max_seq=max_seq, temperature=0.8)
    engine2.load(params)
    out2 = engine2.generate(prompts, max_new_tokens=new_tokens)
    print("sampled (T=0.8):", out2[0].tolist())


if __name__ == "__main__":
    main()

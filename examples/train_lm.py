"""End-to-end LM training driver: a ~100M-param dense transformer
trained for a few hundred steps on synthetic data, with checkpointing,
straggler watchdog, and restart-resume.

Run (full, ~100M params, a few hundred steps — takes a while on CPU):
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 200
Quick CPU demo:
    PYTHONPATH=src python examples/train_lm.py --preset 25m --steps 30
"""
import argparse

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data.pipeline import SyntheticLMData
from repro.models.model_zoo import build_model
from repro.optim.adamw import AdamW
from repro.optim.schedule import warmup_cosine
from repro.train.train_loop import Trainer, init_state, make_train_step

PRESETS = {
    "100m": ModelConfig(
        name="lm-100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=4, d_ff=2048, vocab_size=32768,
        dtype="float32",
    ),
    "25m": ModelConfig(
        name="lm-25m", family="dense", num_layers=8, d_model=384,
        num_heads=6, num_kv_heads=2, d_ff=1024, vocab_size=16384,
        dtype="float32",
    ),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=list(PRESETS), default="25m")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    print(f"model: {cfg.name}  params={cfg.param_count()/1e6:.1f}M")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))

    opt = AdamW(learning_rate=warmup_cosine(3e-4, 20, max(args.steps, 100)))
    state = init_state(params, opt)
    data = SyntheticLMData(cfg.vocab_size, args.seq, args.batch)

    trainer = Trainer(
        train_step=jax.jit(
            make_train_step(api.loss_fn, opt, microbatches=args.microbatches)
        ),
        data=data,
        checkpoint_manager=CheckpointManager(args.ckpt_dir, keep=2, async_save=True),
        checkpoint_every=max(args.steps // 4, 10),
        step_deadline_s=120.0,
        on_straggler=lambda s, dt: print(f"  [watchdog] step {s} took {dt:.1f}s"),
    )
    state = trainer.restore_or_init(state)
    if int(state.step) > 0:
        print(f"resumed from checkpoint at step {int(state.step)}")

    state, hist = trainer.run(state, args.steps)
    trainer.checkpoint_manager.wait()
    for i, h in enumerate(hist):
        if i % max(len(hist) // 10, 1) == 0 or i == len(hist) - 1:
            print(f"step {int(state.step) - len(hist) + i + 1:4d} "
                  f"loss={h['loss']:.4f} gnorm={h['grad_norm']:.3f} {h['sec']:.2f}s")
    print(f"final loss: {hist[-1]['loss']:.4f} (started {hist[0]['loss']:.4f})")


if __name__ == "__main__":
    main()

"""Distributed execution demo on 8 host-platform devices: Axe layouts
drive shardings, collective inference, and the fused GEMM+ReduceScatter.

This script re-execs itself with XLA_FLAGS so the parent environment
keeps a single device.

Run:  PYTHONPATH=src python examples/distributed_demo.py
"""
import os
import sys

if os.environ.get("XLA_FLAGS", "") == "":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.axe import rules as axe_rules
from repro.axe.spec import AxeSpec, PhysicalSpace
from repro.core import DTensorSpec, collective as coll
from repro.kernels import programs
from repro.train import act_sharding


def main():
    mesh = compat.make_mesh((2, 4), ("data", "model"))
    ms = axe_rules.mesh_shape_of(mesh)
    space = PhysicalSpace.from_mesh_shape(ms)
    print("mesh:", ms)

    # --- Axe layout -> sharding for a weight matrix --------------------
    spec = DTensorSpec.from_pspec((1024, 512), (None, "model"), ms)
    print("weight layout:", spec.layout)
    print("as sharding:", spec.sharding(mesh))

    # --- collective inference from a layout pair ----------------------
    src = DTensorSpec.from_pspec((256, 512), ("model", None), ms)
    dst = DTensorSpec.from_pspec((256, 512), (None, "model"), ms)
    plan = coll.infer_redistribution(src, dst, ms)
    print("redistribution plan (model-dim0 -> model-dim1):",
          [type(s).__name__ for s in plan])
    per_dev = coll.plan_comm_bytes(plan, src, ms, 4)
    print(f"  bytes/device: {per_dev}")

    # --- fused GEMM+ReduceScatter: the collective_matmul program ------
    # operand/result AxeSpecs are the only placement input; the ring
    # schedule is the program's "ring" stage variant (docs/kernel-dsl.md)
    a = jax.random.normal(jax.random.PRNGKey(0), (256, 512), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (512, 128), jnp.float32)
    sa = AxeSpec.sharded((256, 512), space, {1: ("model",)})
    sb = AxeSpec.sharded((512, 128), space, {0: ("model",)})
    so = AxeSpec.sharded((256, 128), space, {0: ("model",)})
    f = jax.jit(programs.collective_matmul.shard_map(mesh, (sa, sb), so, impl="ring"))
    out = f(a, b)
    err = float(jnp.max(jnp.abs(out - a @ b)))
    print(f"fused GEMM+RS max err vs dense: {err:.2e}")

    # --- a sharded train-style forward with Axe activation constraints -
    from repro.configs import get_config, smoke_variant
    from repro.models.model_zoo import ShapeSpec, build_model

    cfg = smoke_variant(get_config("qwen3-4b"))
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    pspecs = axe_rules.pspec_tree(axe_rules.param_specs(params, space))
    n_sharded = sum(any(e is not None for e in ps) for ps in jax.tree.leaves(
        pspecs, is_leaf=lambda x: isinstance(x, P)))
    print(f"param tensors with sharded dims: {n_sharded}")
    batch = api.make_train_batch(jax.random.PRNGKey(2), ShapeSpec("s", "train", 64, 4))
    with act_sharding.mesh_context(mesh), mesh:
        loss = jax.jit(api.loss_fn)(params, batch)
    print("sharded forward loss:", float(loss))


if __name__ == "__main__":
    main()

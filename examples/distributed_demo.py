"""Distributed execution demo on 8 host-platform devices: Axe layouts
drive shardings, collective inference, and the fused GEMM+ReduceScatter.

This script re-execs itself with XLA_FLAGS so the parent environment
keeps a single device.

Run:  PYTHONPATH=src python examples/distributed_demo.py
"""
import os
import sys

if os.environ.get("XLA_FLAGS", "") == "":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import DTensorSpec, collective as coll, ops as cops
from repro.train import act_sharding
from repro.train.sharding import mesh_shape_of, param_pspecs


def main():
    mesh = compat.make_mesh((2, 4), ("data", "model"))
    ms = mesh_shape_of(mesh)
    print("mesh:", ms)

    # --- Axe layout -> sharding for a weight matrix --------------------
    spec = DTensorSpec.from_pspec((1024, 512), (None, "model"), ms)
    print("weight layout:", spec.layout)
    print("as sharding:", spec.sharding(mesh))

    # --- collective inference from a layout pair ----------------------
    src = DTensorSpec.from_pspec((256, 512), ("model", None), ms)
    dst = DTensorSpec.from_pspec((256, 512), (None, "model"), ms)
    plan = coll.infer_redistribution(src, dst, ms)
    print("redistribution plan (model-dim0 -> model-dim1):",
          [type(s).__name__ for s in plan])
    per_dev = coll.plan_comm_bytes(plan, src, ms, 4)
    print(f"  bytes/device: {per_dev}")

    # --- fused GEMM+ReduceScatter on the mesh --------------------------
    a = jax.random.normal(jax.random.PRNGKey(0), (256, 512), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (512, 128), jnp.float32)

    def body(a, b):
        return cops.collective_matmul(a, b, axis_name="model", overlap=True)

    f = jax.jit(compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(None, "model"), P("model", None)),
        out_specs=P("model", None), check_vma=False,
    ))
    out = f(a, b)
    err = float(jnp.max(jnp.abs(out - a @ b)))
    print(f"fused GEMM+RS max err vs dense: {err:.2e}")

    # --- a sharded train-style forward with Axe activation constraints -
    from repro.configs import get_config, smoke_variant
    from repro.models.model_zoo import ShapeSpec, build_model

    cfg = smoke_variant(get_config("qwen3-4b"))
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    pspecs = param_pspecs(jax.tree.map(lambda x: x, params), ms)
    n_sharded = sum(any(e is not None for e in ps) for ps in jax.tree.leaves(
        pspecs, is_leaf=lambda x: isinstance(x, P)))
    print(f"param tensors with sharded dims: {n_sharded}")
    batch = api.make_train_batch(jax.random.PRNGKey(2), ShapeSpec("s", "train", 64, 4))
    with act_sharding.mesh_context(mesh), mesh:
        loss = jax.jit(api.loss_fn)(params, batch)
    print("sharded forward loss:", float(loss))


if __name__ == "__main__":
    main()
